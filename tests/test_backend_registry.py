"""Registry completeness: every execution path is a registered ``Backend``
and every registered backend holds the full driver contract *through the
registry interface alone* — no path-specific entry points.

Parametrizing over ``backend_names()`` is the completeness mechanism: a
future sixth backend is pulled into the trajectory-parity and resume-parity
matrices automatically the moment it registers, and a backend that drops
out of the registry fails the explicit roster test. For each backend, via
nothing but ``get_backend(name)``:

* **runner/monolithic parity** — driving the chunked runner to completion
  reproduces ``Backend.run`` bit-identically (including the distributed
  path, whose resume axis has no other in-process coverage — exercised on
  a one-device mesh);
* **resume parity** — handing a mid-run state to a *freshly constructed*
  runner (what a crash-resume does after re-deriving everything from the
  snapshot) continues bit-identically: chunk RNG is a pure function of
  (seed, chunk index), never runner-instance state.

Capability metadata is pinned too: the flags ``resolve_backend`` and the
serving layer dispatch on must match what each path actually supports.
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.core import ising, schedules
from repro.core.backend import (BACKENDS, Backend, backend_names,
                                capability_rows, get_backend, resolve_backend)
from repro.core.solver import SolverConfig
from repro.core.tempering import TemperingConfig

N = 64
STEPS = 120
TRACE = 20
REPLICAS = 4

#: Every execution path this repo ships. A new backend must register (the
#: parametrized parity tests below pick it up from backend_names()); a
#: removed one must be deliberately deleted here.
EXPECTED = ("colored", "distributed", "fused", "reference", "sharded",
            "sharded_2d", "tempering")


def _problem():
    g = np.random.default_rng(0)
    J = np.clip(np.rint(g.normal(size=(N, N)) * 1.5), -3, 3)
    J = np.triu(J, 1)
    J = J + J.T
    h = g.normal(size=(N,)).astype(np.float32)
    return ising.IsingProblem.create(J, h, offset=1.5)


@pytest.fixture(scope="module")
def problem():
    return _problem()


def _scfg():
    return SolverConfig(num_steps=STEPS,
                        schedule=schedules.linear(3.0, 0.1, STEPS),
                        mode="rwa", num_replicas=REPLICAS, trace_every=TRACE)


def _setup(name):
    """(config, mesh) driving backend ``name`` on this machine."""
    from jax.sharding import Mesh

    if name == "tempering":
        cfg = TemperingConfig(num_steps=STEPS, t_min=0.1, t_max=3.0,
                              num_replicas=REPLICAS, swap_every=TRACE,
                              backend="fused")
    elif name == "distributed":
        from repro.distributed.solver_dist import DistSolverConfig
        cfg = DistSolverConfig(base=_scfg(), exchange_every=2)
    elif name == "colored":
        cfg = dataclasses.replace(_scfg(), flip_mode="colored")
    else:
        cfg = _scfg()
    caps = get_backend(name).capabilities
    mesh = None
    if name == "sharded_2d":
        # A degenerate (1, 1) groups×rows mesh still runs the full 2-D code
        # path (group-scoped specs, replica-block slicing) on one device.
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("groups", "rows"))
    elif caps.needs_mesh:
        axis = "spins" if name == "sharded" else "data"
        mesh = Mesh(np.array(jax.devices()[:1]), (axis,))
    return cfg, mesh


def _result_fields(result):
    if hasattr(result, "swap_acceptance"):
        return ("best_energy", "best_spins", "final_energy",
                "swap_acceptance", "num_flips")
    return ("best_energy", "best_spins", "final_energy", "num_flips",
            "trace_energy")


def _assert_same(mono, got):
    for field in _result_fields(mono):
        np.testing.assert_array_equal(
            np.asarray(getattr(mono, field)), np.asarray(getattr(got, field)),
            err_msg=field)


def _drive(runner, *, state=None, rows=None, start=0, stop=None):
    """Run chunks [start, stop) of the duck-typed runner protocol."""
    if state is None:
        state = runner.init()
    rows = list(rows or [])
    stop = runner.total_units if stop is None else stop
    for k in range(start, stop):
        state = runner.run_chunk(state, k)
        if runner.collect_trace:
            rows.append(runner.trace_row(state))
    return state, rows


class TestRoster:
    def test_every_execution_path_is_registered(self):
        assert backend_names() == EXPECTED
        for name in backend_names():
            assert isinstance(get_backend(name), Backend)
            assert get_backend(name).name == name
            assert BACKENDS[name] is get_backend(name)

    def test_unknown_backend_error_lists_the_registry(self):
        with pytest.raises(ValueError, match="registered backends are"):
            get_backend("nope")
        for name in backend_names():
            with pytest.raises(ValueError, match=name):
                get_backend("nope")

    def test_capability_table_covers_every_backend(self):
        rows = capability_rows()
        assert [r[0] for r in rows] == list(backend_names())
        caps = {n: get_backend(n).capabilities for n in backend_names()}
        # The flags serving/resilience dispatch on, per path.
        assert caps["reference"].fixed_fmt == "dense"
        assert not caps["reference"].edge_list
        assert caps["fused"].edge_list and caps["fused"].tier_fallback
        assert caps["fused"].supports_store
        assert caps["colored"].edge_list and caps["colored"].tier_fallback
        assert not caps["colored"].supports_store  # plan replaces the store
        assert not caps["colored"].needs_mesh
        assert caps["sharded"].needs_mesh
        assert caps["sharded"].fixed_fmt == "bitplane_sharded"
        assert caps["sharded_2d"].needs_mesh
        assert caps["sharded_2d"].fixed_fmt == "bitplane_sharded_2d"
        assert not caps["sharded_2d"].auto  # explicit-only: 1-D wins "auto"
        assert caps["distributed"].needs_mesh
        assert caps["tempering"].tier_fallback
        for c in caps.values():
            assert c.supports_resume, "every registered path must resume"

    def test_auto_resolves_from_config_type(self):
        assert resolve_backend(_scfg()) == "fused"
        # flip_mode splits SolverConfig resolution unambiguously.
        assert resolve_backend(
            dataclasses.replace(_scfg(), flip_mode="colored")) == "colored"
        assert resolve_backend(_setup("tempering")[0]) == "tempering"
        dcfg, dmesh = _setup("distributed")
        assert resolve_backend(dcfg, mesh=dmesh) == "distributed"
        cfg, mesh = _setup("sharded")
        assert resolve_backend(cfg, mesh=mesh) == "sharded"
        # A 2-D mesh still auto-resolves to "sharded" (its driver serves
        # multi-axis meshes natively); "sharded_2d" is the explicit name.
        cfg2, mesh2 = _setup("sharded_2d")
        assert resolve_backend(cfg2, mesh=mesh2) == "sharded"
        with pytest.raises(TypeError, match="unrecognized config"):
            resolve_backend(object())

    def test_config_type_mismatch_is_rejected(self):
        with pytest.raises(TypeError, match="TemperingConfig"):
            get_backend("tempering").check_config(_scfg())
        with pytest.raises(TypeError, match="SolverConfig"):
            get_backend("fused").check_config(_setup("tempering")[0])


@pytest.mark.parametrize("name", backend_names())
class TestRegistryParity:
    def test_chunked_runner_matches_monolithic(self, problem, name):
        backend = get_backend(name)
        cfg, mesh = _setup(name)
        mono = backend.run(problem, 7, cfg, mesh=mesh)
        runner = backend.runner(problem, 7, cfg, mesh=mesh)
        state, rows = _drive(runner)
        _assert_same(mono, runner.finalize(state, rows))

    def test_fresh_runner_resumes_bit_identically(self, problem, name):
        """The resume axis, live: a second runner built from scratch (as
        after a crash) continues a saved mid-run state to the identical
        final result for *every* registered backend."""
        backend = get_backend(name)
        cfg, mesh = _setup(name)
        runner = backend.runner(problem, 7, cfg, mesh=mesh)
        assert runner.total_units >= 2, "parity needs a real chunk split"
        split = runner.total_units // 2
        state, rows = _drive(runner, stop=split)
        resumed = backend.runner(problem, 7, cfg, mesh=mesh)
        state, rows = _drive(resumed, state=state, rows=rows, start=split)
        straight, srows = _drive(backend.runner(problem, 7, cfg, mesh=mesh))
        _assert_same(
            backend.runner(problem, 7, cfg, mesh=mesh).finalize(straight,
                                                                srows),
            resumed.finalize(state, rows))


def test_resilient_supervisor_accepts_every_registered_backend(problem):
    """run_resilient's dispatch is the registry, not a hard-coded branch:
    every registered name round-trips through it (smallest viable run)."""
    from repro.core.resilience import STOP_COMPLETED, run_resilient

    for name in backend_names():
        cfg, mesh = _setup(name)
        res = run_resilient(problem, 7, cfg, backend=name, mesh=mesh)
        assert res.stop_reason == STOP_COMPLETED, name
        assert np.isfinite(float(np.min(np.asarray(res.result.best_energy))))


def test_serve_layer_sees_the_same_registry(problem):
    """The serving layer's admission capability checks read the same
    registry objects — a backend registered here is servable there."""
    from repro.serve import ServeConfig, SolverService

    svc = SolverService(ServeConfig())
    r = svc.solve(problem, _scfg(), seed=7, backend="reference")
    assert r.result.best_spins.shape == (REPLICAS, N)
