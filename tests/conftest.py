import numpy as np
import pytest

from benchmarks.subproc import run_forced_device_subprocess

# NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests and benches must
# see the single real CPU device; only launch/dryrun.py forces 512 devices.
# Multi-device cases go through run_with_forced_devices below instead: XLA's
# host device count locks at first jax init, so a forced mesh needs a fresh
# subprocess (env plumbing shared with the sharded bench suite via
# benchmarks/subproc.py).


def run_with_forced_devices(code: str, n_devices: int = 8,
                            timeout: int = 420) -> str:
    """Run ``code`` in a subprocess with a forced multi-device CPU platform
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    The shared harness behind every multi-device tier-1 test — including the
    spin-sharded coupling tier's exact-parity test, which needs a real
    D ≥ 2 mesh rather than a pod. Asserts the subprocess exits cleanly and
    returns its stdout.
    """
    proc = run_forced_device_subprocess(code, n_devices=n_devices,
                                        timeout=timeout)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stderr[-4000:]}"
    return proc.stdout


@pytest.fixture(scope="session")
def forced_device_mesh():
    """Fixture handle on :func:`run_with_forced_devices` — request it to run
    a test body on a forced multi-device CPU mesh."""
    return run_with_forced_devices


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
