import math
import textwrap

import numpy as np
import pytest

from benchmarks.subproc import run_forced_device_subprocess

# NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests and benches must
# see the single real CPU device; only launch/dryrun.py forces 512 devices.
# Multi-device cases go through run_with_forced_devices below instead: XLA's
# host device count locks at first jax init, so a forced mesh needs a fresh
# subprocess (env plumbing shared with the sharded bench suite via
# benchmarks/subproc.py).


def device_mesh_code(mesh_shape, axis_names=None) -> str:
    """Source preamble that binds ``mesh`` over every forced host device.

    ``mesh_shape`` is the device-grid shape: a 1-tuple builds the classic
    1-D row-sharding mesh (axis ``"spins"``); longer shapes build the 2-D
    sharded tier's (groups…, rows) layout — leading replica-group axes,
    trailing ``"rows"`` axis — e.g. ``(2, 2)`` → 4 devices as 2×2. Pass
    ``axis_names`` to override the defaults."""
    shape = tuple(int(s) for s in mesh_shape)
    if axis_names is None:
        if len(shape) == 1:
            axis_names = ("spins",)
        else:
            lead = (("groups",) if len(shape) == 2 else
                    tuple(f"groups{i}" for i in range(len(shape) - 1)))
            axis_names = lead + ("rows",)
    axis_names = tuple(axis_names)
    assert len(axis_names) == len(shape)
    return (
        "import jax as _jax, numpy as _np\n"
        "from jax.sharding import Mesh as _Mesh\n"
        f"assert _jax.device_count() == {math.prod(shape)}\n"
        f"mesh = _Mesh(_np.array(_jax.devices()).reshape({shape!r}), "
        f"{axis_names!r})\n")


def run_with_forced_devices(code: str, n_devices: int = 8,
                            timeout: int = 420, *, mesh_shape=None,
                            axis_names=None) -> str:
    """Run ``code`` in a subprocess with a forced multi-device CPU platform
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    The shared harness behind every multi-device tier-1 test — including the
    spin-sharded coupling tier's exact-parity tests, which need a real
    D ≥ 2 mesh rather than a pod. ``mesh_shape`` (e.g. ``(4,)`` or
    ``(2, 2)``) overrides ``n_devices`` with the shape's device count and
    prepends :func:`device_mesh_code`, so the test body starts with ``mesh``
    already bound — the 1-D and 2-D sharded cases drive one harness.
    Asserts the subprocess exits cleanly and returns its stdout.
    """
    if mesh_shape is not None:
        n_devices = math.prod(tuple(int(s) for s in mesh_shape))
        code = (device_mesh_code(mesh_shape, axis_names)
                + textwrap.dedent(code))
    proc = run_forced_device_subprocess(code, n_devices=n_devices,
                                        timeout=timeout)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stderr[-4000:]}"
    return proc.stdout


@pytest.fixture(scope="session")
def forced_device_mesh():
    """Fixture handle on :func:`run_with_forced_devices` — request it to run
    a test body on a forced multi-device CPU mesh (optionally with a
    pre-built 1-D or 2-D ``mesh`` via ``mesh_shape=``)."""
    return run_with_forced_devices


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
