"""Roofline machinery: loop-aware HLO walker vs known-cost programs, collective
wire-byte parsing, report formatting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis, hlo_cost
from repro.roofline.analysis import CellReport, format_report_table


def test_walker_counts_scan_trip_counts():
    def body(x):
        def f(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(f, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = jax.jit(body).lower(x).compile().as_text()
    c = hlo_cost.analyze(txt)
    assert c.flops == pytest.approx(10 * 2 * 256**3, rel=1e-6)
    assert c.max_trip_product == 10


def test_walker_nested_scans_multiply():
    def nested(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(nested).lower(x).compile().as_text()
    c = hlo_cost.analyze(txt)
    assert c.flops == pytest.approx(12 * 2 * 128**3, rel=1e-6)


def test_walker_bytes_at_least_io():
    def mm(a):
        return a @ a

    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    txt = jax.jit(mm).lower(x).compile().as_text()
    c = hlo_cost.analyze(txt)
    assert c.bytes >= 3 * 512 * 512 * 4  # 2 reads (same arg) + 1 write
    assert c.bytes_fused >= 3 * 512 * 512 * 4
    assert c.bytes_fused <= c.bytes + 1


def test_collective_wire_bytes_formulas():
    hlo = """
HloModule m
ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%a), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = f32[4096]{0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[1024]{0} reduce-scatter(%ag), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %cp = f32[1024]{0} collective-permute(%rs), source_target_pairs={{0,1},{1,0}}
}
"""
    stats = analysis.collective_bytes(hlo, default_group=4)
    b = 1024 * 4
    assert stats.op_bytes["all-reduce"] == pytest.approx(2 * b * 3 / 4)
    assert stats.op_bytes["all-gather"] == pytest.approx(4 * b * 3 / 4)
    assert stats.op_bytes["reduce-scatter"] == pytest.approx(b * 3)
    assert stats.op_bytes["collective-permute"] == pytest.approx(b)


def test_cell_report_bottleneck_and_mfu():
    r = CellReport(
        arch="x", shape="train_4k", mesh="pod", num_devices=256,
        device_flops=1e12, device_bytes=1e9, wire_bytes=1e6,
        t_compute=1e12 / analysis.HW["peak_flops_bf16"],
        t_memory=1e9 / analysis.HW["hbm_bw"],
        t_collective=1e6 / analysis.HW["ici_bw"],
        bottleneck="compute", model_flops=256 * 0.9e12, useful_ratio=0.9,
        memory_per_device={"arguments": 1, "outputs": 1, "temps": 1, "aliased": 0},
        collective_ops={})
    assert r.step_time == max(r.t_compute, r.t_memory, r.t_collective)
    assert 0.0 < r.mfu <= 1.0
    table = format_report_table([r])
    assert "train_4k" in table and "compute" in table


def test_dtype_byte_table_consistency():
    assert hlo_cost._DTYPE_BYTES["bf16"] == 2
    assert hlo_cost._DTYPE_BYTES["f32"] == 4
    assert analysis._DTYPE_BYTES["bf16"] == 2
