"""Property tests for ``ops.anneal_chunk_plan`` and the per-chunk RNG
stream — the two invariants every chunked driver leans on:

* **coverage** — the (chunk_len, num_chunks, rem_steps) plan accounts for
  exactly ``num_steps`` untraced, and exactly ``num_chunks·trace_every``
  traced (the documented trace cadence, shared with the reference scan);
* **stream purity** — the chunk key ``stream(base(seed), Salt.SWEEP, c)``
  is a pure function of (seed, chunk index): distinct across chunks,
  reproducible from scratch, independent of evaluation order.

Resume parity (a freshly built runner continuing mid-run) and the 2-D
sharded path (every group re-deriving its replica block from the full-R
stream) are both downstream of these — see DESIGN.md §Resilient solves.

Randomized sweeps over a seeded generator rather than hypothesis (not in
the environment): the case set is deterministic, wide, and printed on
failure.
"""
import numpy as np

import jax

from repro.core import rng, schedules
from repro.core.solver import SolverConfig
from repro.kernels import ops


def _cfg(num_steps: int, trace_every: int) -> SolverConfig:
    return SolverConfig(num_steps=num_steps,
                        schedule=schedules.linear(3.0, 0.1, num_steps),
                        num_replicas=2, trace_every=trace_every)


def _cases(seed, n, *, traced):
    g = np.random.default_rng(seed)
    for _ in range(n):
        num_steps = int(g.integers(1, 5000))
        chunk_steps = int(g.integers(1, 700))
        trace_every = int(g.integers(1, 400)) if traced else 0
        yield num_steps, chunk_steps, trace_every


def test_untraced_chunks_exactly_cover_num_steps():
    """Untraced plans partition num_steps exactly: full chunks plus one
    remainder sweep strictly shorter than a chunk."""
    for num_steps, chunk_steps, _ in _cases(0, 300, traced=False):
        cl, nc, rem = ops.anneal_chunk_plan(_cfg(num_steps, 0), chunk_steps)
        case = f"num_steps={num_steps} chunk_steps={chunk_steps} -> {cl, nc, rem}"
        assert cl * nc + rem == num_steps, case
        assert 1 <= cl <= max(min(chunk_steps, num_steps), 1), case
        assert nc >= 1 and 0 <= rem < cl, case


def test_traced_chunks_follow_trace_cadence():
    """Traced plans pin chunk_len to trace_every with no remainder — the
    trace records at every chunk end, identically to the reference scan
    (total steps = num_chunks·trace_every by that shared contract)."""
    for num_steps, chunk_steps, trace_every in _cases(1, 300, traced=True):
        cfg = _cfg(num_steps, trace_every)
        cl, nc, rem = ops.anneal_chunk_plan(cfg, chunk_steps)
        case = (f"num_steps={num_steps} chunk_steps={chunk_steps} "
                f"trace_every={trace_every} -> {cl, nc, rem}")
        assert cl == trace_every and rem == 0, case
        assert nc == max(num_steps // trace_every, 1), case
        # chunk_steps is a perf knob for untraced runs only.
        assert ops.anneal_chunk_plan(cfg, chunk_steps * 2 + 1) == (cl, nc, rem)


def test_plan_is_deterministic_and_total_units_consistent():
    """Same config -> same plan, and the runner-facing unit count
    (num_chunks + remainder unit) covers every step exactly once."""
    for num_steps, chunk_steps, trace_every in _cases(2, 200, traced=False):
        cfg = _cfg(num_steps, trace_every)
        plan = ops.anneal_chunk_plan(cfg, chunk_steps)
        assert plan == ops.anneal_chunk_plan(cfg, chunk_steps)
        cl, nc, rem = plan
        unit_lens = [cl] * nc + ([rem] if rem else [])
        assert sum(unit_lens) == num_steps


def _chunk_key(seed: int, c: int) -> np.ndarray:
    """The exact per-chunk key derivation every chunked driver uses
    (``_fused_chunk`` / ``_colored_chunk`` / ``_sharded_chunk_inputs``):
    base = fold_in(key(0), seed); chunk key = stream(base, SWEEP, c)."""
    base = jax.random.fold_in(jax.random.key(0), np.uint32(seed))
    return np.asarray(jax.random.key_data(
        rng.stream(base, rng.Salt.SWEEP, c)))


def test_chunk_keys_distinct_across_chunks_and_seeds():
    """No two (seed, chunk) pairs share a SWEEP key across a wide sweep —
    chunk uniforms never repeat within or across runs."""
    keys = np.stack([_chunk_key(seed, c)
                     for seed in (0, 1, 5, 2**31, 2**32 - 1)
                     for c in range(64)])
    assert len(np.unique(keys, axis=0)) == len(keys)


def test_chunk_keys_are_pure_functions_of_seed_and_index():
    """Key(seed, c) recomputed from scratch is bit-identical, and never
    depends on which other chunks were derived first — the property that
    lets a resumed run (or a 2-D group slicing its replica block) rebuild
    chunk c's uniforms without replaying chunks 0..c-1."""
    g = np.random.default_rng(3)
    for _ in range(50):
        seed = int(g.integers(0, 2**32))
        c = int(g.integers(0, 10_000))
        first = _chunk_key(seed, c)
        np.testing.assert_array_equal(first, _chunk_key(seed, c))
        # Deriving unrelated chunks in between must not perturb it.
        _chunk_key(seed, c + 1), _chunk_key(seed + 1, c)
        np.testing.assert_array_equal(first, _chunk_key(seed, c))


def test_chunk_uniforms_match_contiguous_stream_slices():
    """Drawing chunk c's uniforms in isolation reproduces exactly what a
    monolithic run drew for those steps: the fused scan, the resilient
    runner, and every sharded group (full-R draw, block slice) all read
    the same numbers for chunk c regardless of who computes them."""
    r = 4
    for seed in (0, 11):
        per_chunk = [
            np.asarray(rng.uniform01(
                jax.random.wrap_key_data(jax.numpy.asarray(
                    _chunk_key(seed, c))), (8, r, 4)))
            for c in range(5)]
        again = [
            np.asarray(rng.uniform01(
                jax.random.wrap_key_data(jax.numpy.asarray(
                    _chunk_key(seed, c))), (8, r, 4)))
            for c in range(5)]
        for a, b in zip(per_chunk, again):
            np.testing.assert_array_equal(a, b)
        # Distinct chunks draw distinct tensors (same shape, same seed).
        flat = np.stack([u.ravel() for u in per_chunk])
        assert len(np.unique(flat, axis=0)) == len(flat)
