"""Hamiltonian / local-field / incremental-update correctness (paper §II, Eq. 11-12)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips only @given tests when absent

from repro.core import ising


def _random_problem(rng, n, int_weights=False, field_scale=1.0):
    J = rng.normal(size=(n, n)).astype(np.float32)
    if int_weights:
        J = np.rint(J * 3)
    J = np.triu(J, 1)
    J = J + J.T
    h = (rng.normal(size=n) * field_scale).astype(np.float32)
    return ising.IsingProblem.create(J=J, h=h)


def test_energy_matches_paper_figure2_example():
    # Figure 2: K5 with the ground state s = (+1,+1,-1,+1,-1), H = -24 = -14 - 10.
    # Construct *a* K5 instance consistent with that account: couplings and
    # fields chosen so pair term = -14, field term = -10 at the given s.
    s = np.array([1, 1, -1, 1, -1], np.float32)
    rngl = np.random.default_rng(3)
    for _ in range(20):
        J = np.rint(rngl.normal(size=(5, 5)) * 2)
        J = np.triu(J, 1) + np.triu(J, 1).T
        pair = -0.5 * s @ J @ s
        if pair == 0:
            continue
        J = J * (-14.0 / pair)
        h = np.rint(rngl.normal(size=5) * 2)
        if h @ s == 0:
            continue
        h = h * (10.0 / (h @ s))  # field term -h·s = -10
        prob = ising.IsingProblem.create(J=J, h=h, check=False)
        e = float(ising.energy(prob, jnp.asarray(s, jnp.int8)))
        assert e == pytest.approx(-24.0, rel=1e-5)
        return
    pytest.fail("could not construct example")


def test_energy_definition_pairwise_sum(rng):
    prob = _random_problem(rng, 9)
    s = np.asarray(ising.random_spins(jax.random.key(1), (9,)))
    J = np.asarray(prob.couplings)
    h = np.asarray(prob.fields)
    ref = -sum(J[i, j] * s[i] * s[j] for i in range(9) for j in range(i + 1, 9)) - h @ s
    got = float(ising.energy(prob, jnp.asarray(s)))
    assert got == pytest.approx(float(ref), rel=1e-5)


def test_local_fields_definition(rng):
    prob = _random_problem(rng, 11)
    s = np.asarray(ising.random_spins(jax.random.key(2), (11,)))
    u = np.asarray(ising.local_fields(prob, jnp.asarray(s)))
    J = np.asarray(prob.couplings)
    h = np.asarray(prob.fields)
    for i in range(11):
        ref = h[i] + sum(J[i, j] * s[j] for j in range(11) if j != i)
        assert u[i] == pytest.approx(float(ref), rel=1e-4, abs=1e-4)


def test_delta_energy_is_flip_difference(rng):
    prob = _random_problem(rng, 8)
    s = np.asarray(ising.random_spins(jax.random.key(3), (8,)))
    dE = np.asarray(ising.delta_energies(prob, jnp.asarray(s)))
    e0 = float(ising.energy(prob, jnp.asarray(s)))
    for i in range(8):
        s2 = s.copy()
        s2[i] = -s2[i]
        e1 = float(ising.energy(prob, jnp.asarray(s2)))
        assert dE[i] == pytest.approx(e1 - e0, rel=1e-4, abs=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 24), st.integers(1, 64))
def test_incremental_field_update_matches_recompute(seed, n, num_flips):
    """Paper Eq. 12: Θ(N) incremental update == Θ(N²) recompute, under any flip sequence."""
    rngl = np.random.default_rng(seed)
    J = rngl.normal(size=(n, n)).astype(np.float32)
    J = np.triu(J, 1)
    J = J + J.T
    h = rngl.normal(size=n).astype(np.float32)
    prob = ising.IsingProblem.create(J=J, h=h)
    s = np.where(rngl.random(n) < 0.5, 1, -1).astype(np.int8)
    u = np.asarray(ising.local_fields(prob, jnp.asarray(s)))
    for _ in range(num_flips):
        j = int(rngl.integers(n))
        u = np.asarray(ising.incremental_field_update(
            prob.couplings, jnp.asarray(u), jnp.int32(j), jnp.asarray(s[j])))
        s[j] = -s[j]
    ref = np.asarray(ising.local_fields(prob, jnp.asarray(s)))
    np.testing.assert_allclose(u, ref, rtol=1e-4, atol=1e-3)


def test_brute_force_ground_state_small():
    # Ferromagnetic chain: ground states are all-up / all-down, E = -(n-1).
    n = 6
    J = np.zeros((n, n), np.float32)
    for i in range(n - 1):
        J[i, i + 1] = J[i + 1, i] = 1.0
    prob = ising.IsingProblem.create(J=J)
    e, s, all_e = ising.brute_force_ground_state(prob)
    assert e == pytest.approx(-(n - 1))
    assert np.all(s == s[0])
    assert all_e.shape == (2**n,)


def test_validation_rejects_bad_inputs():
    with pytest.raises(ValueError):
        ising.IsingProblem.create(J=np.ones((3, 3), np.float32))  # nonzero diagonal
    J = np.zeros((3, 3), np.float32)
    J[0, 1] = 1.0  # asymmetric
    with pytest.raises(ValueError):
        ising.IsingProblem.create(J=J)


def test_validation_rejects_non_finite_naming_entry():
    """A NaN/inf must be reported by coordinate — not surface as the
    misleading 'J must be symmetric' (NaN != NaN under allclose)."""
    J = np.zeros((4, 4), np.float32)
    J[1, 2] = J[2, 1] = np.nan
    with pytest.raises(ValueError, match=r"J must be finite: J\[1, 2\]"):
        ising.IsingProblem.create(J=J)
    J = np.zeros((4, 4), np.float32)
    J[0, 3] = J[3, 0] = np.inf
    with pytest.raises(ValueError, match=r"J\[0, 3\] = inf"):
        ising.IsingProblem.create(J=J)
    h = np.zeros((4,), np.float32)
    h[2] = np.nan
    with pytest.raises(ValueError, match=r"h must be finite: h\[2\]"):
        ising.IsingProblem.create(J=np.zeros((4, 4), np.float32), h=h)


def test_edge_list_rejects_bad_weights_naming_edge():
    rows = np.array([0, 1, 2])
    cols = np.array([1, 2, 3])
    w = np.array([1.0, np.nan, 2.0])
    with pytest.raises(ValueError,
                       match=r"edge #1 \(1, 2\) has weight nan"):
        ising.EdgeList.create(rows, cols, w, 4)
    w = np.array([1.0, np.inf, -np.inf])
    with pytest.raises(ValueError, match=r"\+1 more non-finite"):
        ising.EdgeList.create(rows, cols, w, 4)
    w = np.array([1.0, 2.0, 0.25])
    with pytest.raises(ValueError,
                       match=r"integer weights.*edge #2 \(2, 3\)"):
        ising.EdgeList.create(rows, cols, w, 4)


def test_edge_list_content_hash_is_canonicalization_stable():
    """The content hash the serving caches key on (``_digest`` / ``__hash__``
    / ``__eq__``) is a function of the canonical edge set, not the input
    order or encoding: a permuted triple, flipped (j, i) entries, and a
    weight split across duplicate entries (duplicates sum) all canonicalize
    to the same EdgeList and hash identically — while any real content
    change (a weight, the spin count) changes the hash."""
    rows = np.array([0, 1, 2, 0])
    cols = np.array([1, 2, 3, 2])
    w = np.array([2, -3, 4, 6])
    a = ising.EdgeList.create(rows, cols, w, 8)

    perm = np.array([3, 1, 0, 2])
    b = ising.EdgeList.create(rows[perm], cols[perm], w[perm], 8)
    flipped = ising.EdgeList.create(cols, rows, w, 8)  # (j, i) = same edges
    split = ising.EdgeList.create(                     # (2, 3): 4 = 1 + 3
        np.array([0, 1, 2, 0, 2]), np.array([1, 2, 3, 2, 3]),
        np.array([2, -3, 1, 6, 3]), 8)
    for other in (b, flipped, split):
        assert other == a
        assert hash(other) == hash(a)
        assert other._digest == a._digest

    reweighted = ising.EdgeList.create(rows, cols, np.array([2, -3, 5, 6]), 8)
    wider = ising.EdgeList.create(rows, cols, w, 9)
    assert reweighted != a and hash(reweighted) != hash(a)
    assert wider != a and wider._digest != a._digest
