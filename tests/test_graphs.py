"""Graph substrate: generators, Max-Cut/QUBO mappings, Gset parser, placement."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips only @given tests when absent

import jax.numpy as jnp

from repro.core import ising, placement
from repro.graphs import (GSET_SAMPLE, MaxCutInstance, complete_bipolar, cut_value,
                          erdos_renyi, ising_to_qubo, maxcut_to_ising, parse_gset,
                          qubo_to_ising, small_world, torus_grid)
from repro.graphs.generators import ground_state_planted_grid
from repro.graphs.maxcut import cut_from_energy, energy_from_cut
from repro.graphs.qubo import qubo_energy


def test_generator_statistics_match_table1_families():
    g6 = erdos_renyi(80, 192, seed=0)   # scaled G6: n=800,|E|=19176 -> /10
    assert g6.num_vertices == 80 and g6.num_edges == 192
    sw = small_world(80, 6, seed=0)
    assert sw.num_vertices == 80 and sw.num_edges > 0
    tg = torus_grid(8, 10)
    assert tg.num_vertices == 80 and tg.num_edges == 160  # 2 edges per vertex
    k = complete_bipolar(50, seed=0)
    assert k.num_edges == 50 * 49 // 2 and k.density == 1.0
    w = np.asarray(k.weights)
    assert set(np.unique(w[np.triu_indices(50, 1)])) == {-1.0, 1.0}


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6), st.integers(3, 12))
def test_maxcut_energy_cut_duality(seed, n):
    """cut(s) == (Σw − H(s))/2 for J = −w (paper §II-B mapping)."""
    rng = np.random.default_rng(seed)
    w = np.triu(rng.integers(-3, 4, size=(n, n)).astype(np.float32), 1)
    w = w + w.T
    inst = MaxCutInstance(weights=w)
    prob = maxcut_to_ising(inst)
    s = np.where(rng.random(n) < 0.5, 1, -1).astype(np.int8)
    h_val = float(ising.energy(prob, jnp.asarray(s)))
    assert cut_value(inst, s) == pytest.approx(float(cut_from_energy(inst, h_val)), abs=1e-3)
    assert energy_from_cut(inst, cut_value(inst, s)) == pytest.approx(h_val, abs=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 10))
def test_qubo_ising_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    Q = rng.normal(size=(n, n))
    prob = qubo_to_ising(Q)
    for _ in range(8):
        x = (rng.random(n) < 0.5).astype(np.float64)
        s = (2 * x - 1).astype(np.int8)
        e_ising = float(ising.energy(prob, jnp.asarray(s))) + prob.offset
        assert e_ising == pytest.approx(qubo_energy(Q, x), rel=1e-4, abs=1e-4)
    Q2, off2 = ising_to_qubo(prob)
    x = (rng.random(n) < 0.5).astype(np.float64)
    s = (2 * x - 1).astype(np.int8)
    assert qubo_energy(Q2, x) + off2 == pytest.approx(
        float(ising.energy(prob, jnp.asarray(s))) + prob.offset, rel=1e-4, abs=1e-4)


def test_gset_parser_roundtrip():
    inst = parse_gset(GSET_SAMPLE, name="sample")
    assert inst.num_vertices == 10 and inst.num_edges == 14
    assert inst.weights[0, 1] == 1.0 and inst.weights[2, 0] == -1.0
    assert np.allclose(inst.weights, inst.weights.T)


def test_gset_parser_rejects_bad_edge_count():
    bad = "3 2\n1 2 1\n"
    with pytest.raises(ValueError):
        parse_gset(bad)


def test_planted_ground_state_is_optimal():
    inst, planted = ground_state_planted_grid(4, 4, seed=1)
    best = cut_value(inst, planted)
    assert best == pytest.approx(inst.best_known)
    # No single-flip improvement exists at the plant (local optimality).
    for i in range(16):
        s2 = planted.copy()
        s2[i] = -s2[i]
        assert cut_value(inst, s2) <= best + 1e-6


def test_placement_beats_random_and_balances():
    rng = np.random.default_rng(0)
    # Two clusters of experts with heavy intra-cluster traffic.
    E = 16
    C = rng.random((E, E)) * 0.1
    C[:8, :8] += 5.0
    C[8:, 8:] += 5.0
    C = np.triu(C, 1)
    C = C + C.T
    res = placement.place(C, num_devices=2, seed=0, steps=1500, replicas=4)
    rand_cuts = [placement.cut_bytes(C, rng.integers(0, 2, E)) for _ in range(20)]
    assert res.cut_bytes < min(rand_cuts)
    assert res.imbalance < 0.26
    counts = np.bincount(res.assignment, minlength=2)
    assert counts.min() >= 6  # near-balanced bisection


def test_placement_four_devices():
    rng = np.random.default_rng(1)
    E = 16
    C = np.triu(rng.random((E, E)), 1)
    C = C + C.T
    res = placement.place(C, num_devices=4, seed=0, steps=800, replicas=4)
    assert set(np.unique(res.assignment)) == {0, 1, 2, 3}
    assert res.cut_bytes >= 0


def test_parse_gset_edges_matches_dense_parser():
    """The dense-J-free Gset pipeline: parse_gset_edges → EdgeList of weights
    → maxcut_edges_to_ising(J = −w) must describe exactly the instance the
    dense parser + dense mapping builds — without any (N, N) array."""
    from repro.graphs import parse_gset, parse_gset_edges
    from repro.graphs.maxcut import maxcut_edges_to_ising, maxcut_to_ising

    dense = parse_gset(GSET_SAMPLE)
    edges = parse_gset_edges(GSET_SAMPLE)
    assert edges.num_spins == dense.num_vertices
    assert edges.nnz == dense.num_edges
    np.testing.assert_array_equal(edges.to_dense(), dense.weights)
    prob_sparse = maxcut_edges_to_ising(edges)
    prob_dense = maxcut_to_ising(dense)
    assert prob_sparse.couplings is None and prob_sparse.edges is not None
    np.testing.assert_array_equal(prob_sparse.edges.to_dense(),
                                  np.asarray(prob_dense.couplings))
    assert prob_sparse.offset == prob_dense.offset
    with pytest.raises(TypeError, match="EdgeList"):
        maxcut_edges_to_ising(dense.weights)
    # Header/edge-count mismatch is caught like the dense parser's.
    bad = GSET_SAMPLE.replace("10 14", "10 15", 1)
    with pytest.raises(ValueError, match="declared"):
        parse_gset_edges(bad)
    # A duplicated edge line (either orientation) is the one input on which
    # sum-coalescing and the dense parser's last-wins would diverge — the
    # sparse parser refuses it instead of silently solving a different
    # instance.
    dup = GSET_SAMPLE.replace("10 14", "10 15", 1) + "2 1 1\n"
    with pytest.raises(ValueError, match="duplicate"):
        parse_gset_edges(dup)


def test_sparse_bipolar_edges_generator():
    from repro.graphs import sparse_bipolar_edges

    e = sparse_bipolar_edges(256, 1024, seed=3)
    assert e.num_spins == 256
    assert 0 < e.nnz <= 1024
    assert e.max_abs_weight == 1          # signs assigned after dedup
    assert (e.rows < e.cols).all()
    # Deterministic in the seed.
    assert e == sparse_bipolar_edges(256, 1024, seed=3)
    assert e != sparse_bipolar_edges(256, 1024, seed=4)
