"""Statistical-correctness tier: the fused backend must not just match its
oracle step-for-step — it must sample the *right distribution*.

Exact step-parity (test_backend_parity) catches layout/arithmetic divergence
but is blind to acceptance-rule bugs that both engines share: a sign error in
ΔE, a mis-scaled flip probability, or a broken uniformization would still be
"exactly equal" between kernel and oracle while silently sampling the wrong
chain. This tier closes that hole on an exactly-enumerable instance (N ≤ 12):

- Long fixed-temperature fused chains (RSA and uniformized-RWA — the two
  modes whose transition kernels satisfy detailed balance w.r.t. the
  Boltzmann measure; plain RWA is rejection-free and deliberately biased)
  must reproduce the enumerated Boltzmann distribution in chi-squared and
  total-variation distance, with power checks against wrong-temperature
  nulls. The long chains run behind ``-m slow``.
- At T=0 the chain degenerates to stochastic greedy descent: energy must be
  monotone non-increasing at every chunk boundary (default tier — cheap).

Chains are fully deterministic given the seed (stateless threefry streams),
so the thresholds are calibrated, not flaky.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ising, rng
from repro.kernels import ops


def _tiny_problem(seed=11, n=6, scale=1.2):
    g = np.random.default_rng(seed)
    J = np.rint(g.normal(size=(n, n)) * scale)
    J = np.triu(J, 1)
    J = (J + J.T).astype(np.float32)
    h = np.rint(g.normal(size=n)).astype(np.float32)
    return ising.IsingProblem.create(J=J, h=h)


def _enumerate_boltzmann(problem, temp):
    """Exact Boltzmann p(s) ∝ exp(−E(s)/T) over all 2^N configurations."""
    n = problem.num_spins
    idx = np.arange(2 ** n)
    spins = np.where((idx[:, None] >> np.arange(n)) & 1, 1.0, -1.0).astype(np.float32)
    e = np.asarray(ising.energy(problem, jnp.asarray(spins)))
    w = np.exp(-(e - e.min()) / temp)
    return w / w.sum()


def _state_index(spins):
    """Map ±1 spin rows to the enumeration index (bit j set ⇔ s_j = +1)."""
    bits = (np.asarray(spins) > 0).astype(np.int64)
    return bits @ (1 << np.arange(bits.shape[-1], dtype=np.int64))


def _chain_energies_and_samples(problem, temp, *, mode, uniformized, r,
                                chunk, num_chunks, burn_chunks, seed=3):
    """Run the fused backend at fixed T as ``num_chunks`` sweep chunks and
    record the chain state at every post-burn-in chunk boundary, pooled over
    the R independent replicas. Uses the production chunk driver + RNG
    streams (``Salt.SWEEP``) so the chain under test is exactly the one
    ``solve(backend="fused")`` runs."""
    base = jax.random.fold_in(jax.random.key(0), jnp.uint32(seed))
    state = ops.fused_init_state(problem, base, r, interpret=True)
    temps = jnp.full((chunk, r), temp, jnp.float32)
    samples, energies = [], []
    for c in range(num_chunks):
        state = ops.fused_sweep_chunk(
            problem.couplings, state, rng.stream(base, rng.Salt.SWEEP, c),
            chunk, temps, mode=mode, uniformized=uniformized, pwl_table=None,
            block_r=r, interpret=True)
        energies.append(np.asarray(state[2]))  # (R,) current energy
        if c >= burn_chunks:
            samples.append(_state_index(state[1]))
    pooled = (np.concatenate(samples) if samples
              else np.zeros((0,), np.int64))
    return np.stack(energies), pooled


def _tv_distance(counts, p_exact):
    emp = counts / counts.sum()
    return 0.5 * np.abs(emp - p_exact).sum()


def _chi2_statistic(counts, p_exact):
    """Pearson X² with low-expectation states pooled into one bin (the
    classical ≥5-expected-counts rule). Returns (X², degrees of freedom)."""
    m = counts.sum()
    expected = p_exact * m
    big = expected >= 5.0
    obs = np.append(counts[big], counts[~big].sum())
    exp = np.append(expected[big], expected[~big].sum())
    keep = exp > 0
    obs, exp = obs[keep], exp[keep]
    return float(((obs - exp) ** 2 / exp).sum()), len(obs) - 1


def _chi2_critical(df, alpha=1e-4):
    """Upper-tail chi-squared critical value; scipy when present, else the
    Wilson–Hilferty normal approximation (good to a few % at these df)."""
    try:
        from scipy.stats import chi2
        return float(chi2.ppf(1.0 - alpha, df))
    except ImportError:  # pragma: no cover - scipy-less hosts
        from math import erf, sqrt
        # invert Φ via bisection on erf — crude but dependency-free
        lo, hi = 0.0, 10.0
        target = 1.0 - alpha
        for _ in range(80):
            mid = (lo + hi) / 2
            if 0.5 * (1 + erf(mid / sqrt(2))) < target:
                lo = mid
            else:
                hi = mid
        z = (lo + hi) / 2
        return df * (1 - 2 / (9 * df) + z * np.sqrt(2 / (9 * df))) ** 3


#: (mode, uniformized) pairs whose transition kernels are Boltzmann-stationary:
#: RSA is random-scan Glauber; uniformized RWA is the paper's §IV-B3c
#: uniformization of the Glauber-rate CTMC (W* = N). Plain RWA is
#: rejection-free by construction and intentionally not Boltzmann-exact.
BOLTZMANN_MODES = [("rsa", False), ("rwa", True)]


@pytest.mark.slow
@pytest.mark.parametrize("mode,uniformized", BOLTZMANN_MODES)
def test_fused_chain_samples_boltzmann(mode, uniformized):
    problem = _tiny_problem()
    temp = 2.5
    n = problem.num_spins
    p_exact = _enumerate_boltzmann(problem, temp)
    _, idx = _chain_energies_and_samples(
        problem, temp, mode=mode, uniformized=uniformized, r=16,
        chunk=48, num_chunks=520, burn_chunks=40)
    counts = np.bincount(idx, minlength=2 ** n).astype(np.float64)

    # Chi-squared goodness of fit. Chunk-boundary samples retain a little
    # autocorrelation (48 steps ≈ 8 sweeps apart), so the gate is a deep-tail
    # critical value rather than the 5% one — a sign/scale bug in the
    # acceptance rule inflates X² by orders of magnitude, not percent.
    x2, df = _chi2_statistic(counts, p_exact)
    assert x2 < 2.0 * _chi2_critical(df), (x2, df)

    # Total-variation gate with power controls: the empirical law must sit
    # close to the true temperature and clearly closer than wrong-T nulls.
    tv = _tv_distance(counts, p_exact)
    assert tv < 0.05, tv
    for wrong_temp in (temp * 2.0, temp * 0.5):
        tv_wrong = _tv_distance(counts, _enumerate_boltzmann(problem, wrong_temp))
        assert tv_wrong > 3.0 * tv, (tv, tv_wrong, wrong_temp)


@pytest.mark.slow
def test_uniformized_rwa_matches_rsa_distribution():
    """The two Boltzmann-stationary modes must agree with *each other* — a
    bug in just one mode's acceptance rule shows up as a cross-mode TV gap
    even if both pass the marginal gates."""
    problem = _tiny_problem()
    temp = 2.5
    n = problem.num_spins
    counts = {}
    for mode, uniformized in BOLTZMANN_MODES:
        _, idx = _chain_energies_and_samples(
            problem, temp, mode=mode, uniformized=uniformized, r=16,
            chunk=48, num_chunks=520, burn_chunks=40)
        counts[mode] = np.bincount(idx, minlength=2 ** n).astype(np.float64)
    emp_rsa = counts["rsa"] / counts["rsa"].sum()
    tv_cross = 0.5 * np.abs(emp_rsa - counts["rwa"] / counts["rwa"].sum()).sum()
    assert tv_cross < 0.07, tv_cross


@pytest.mark.parametrize("mode,uniformized", BOLTZMANN_MODES)
def test_zero_temperature_descent_is_monotone(mode, uniformized):
    """T=0 collapses the chain to stochastic greedy descent (flip iff
    ΔE ≤ 0): the per-replica energy trajectory must never increase across
    chunk boundaries, and the final bookkeeping must match a fresh energy
    recomputation from the spins."""
    problem = _tiny_problem(seed=5, n=10)
    energies, _ = _chain_energies_and_samples(
        problem, 0.0, mode=mode, uniformized=uniformized, r=8,
        chunk=16, num_chunks=12, burn_chunks=12)
    assert np.isfinite(energies).all()
    assert (np.diff(energies, axis=0) <= 1e-6).all(), \
        "zero-T fused chain increased energy"


def _tiny_sparse_problem(seed=13, n=7, m=10):
    """Small random sparse instance (edge-list) with integer weights/fields —
    sparse so the coloring is non-trivial (χ ≥ 2 classes of several spins),
    tiny so the Boltzmann law is exactly enumerable."""
    g = np.random.default_rng(seed)
    i = g.integers(0, n, size=m)
    j = g.integers(0, n, size=m)
    keep = i != j
    w = g.choice([-2, -1, 1, 2], size=m)
    edges = ising.EdgeList.create(i[keep], j[keep], w[keep], n)
    h = np.rint(g.normal(size=n)).astype(np.float32)
    return ising.IsingProblem.create_sparse(edges, h=h)


def _colored_chain_energies_and_samples(problem, temp, *, r, chunk,
                                        num_chunks, burn_chunks, seed=3):
    """Colored counterpart of :func:`_chain_energies_and_samples`: fixed-T
    chain driven through the production colored chunk machinery (plan store,
    ``Salt.SWEEP`` streams, absolute-step class schedule). Returns samples in
    the plan's color-sorted order together with the matching permuted dense
    problem, so callers enumerate the Boltzmann law in the same basis."""
    plan = ops.colored_plan(problem, "bitplane")
    pdense = ising.IsingProblem.create(
        jnp.asarray(plan.problem.edges.to_dense()), h=plan.problem.fields)
    base = jax.random.fold_in(jax.random.key(0), jnp.uint32(seed))
    state = ops.fused_init_state(plan.problem, base, r, interpret=True,
                                 planes=plan.store.planes)
    temps = jnp.full((chunk, r), temp, jnp.float32)
    samples, energies = [], []
    for c in range(num_chunks):
        sched = ops.colored_class_schedule(
            plan.wstarts, plan.offsets, plan.sizes,
            jnp.arange(chunk) + c * chunk)
        state = ops.colored_sweep_chunk(
            plan.store.kernel_operand, state,
            rng.stream(base, rng.Salt.SWEEP, c), chunk, temps, sched,
            window=plan.window, coupling=plan.store.fmt, block_r=r,
            interpret=True)
        energies.append(np.asarray(state[2]))
        if c >= burn_chunks:
            samples.append(_state_index(state[1]))
    pooled = (np.concatenate(samples) if samples
              else np.zeros((0,), np.int64))
    return np.stack(energies), pooled, pdense


@pytest.mark.slow
def test_colored_chain_samples_boltzmann():
    """Colored block updates are exact Gibbs — same-color spins share no
    coupling, so flipping a whole class from heat-bath coins is a valid
    blocked Gibbs sweep and the fixed-T chain must be Boltzmann-stationary.
    Same gates and wrong-temperature power controls as the single-flip tier:
    a conflict in the coloring (two coupled spins updated from stale fields)
    biases the law and fails TV/χ² by a wide margin."""
    problem = _tiny_sparse_problem()
    temp = 2.5
    n = problem.num_spins
    _, idx, pdense = _colored_chain_energies_and_samples(
        problem, temp, r=16, chunk=48, num_chunks=520, burn_chunks=40)
    p_exact = _enumerate_boltzmann(pdense, temp)
    counts = np.bincount(idx, minlength=2 ** n).astype(np.float64)

    x2, df = _chi2_statistic(counts, p_exact)
    assert x2 < 2.0 * _chi2_critical(df), (x2, df)

    tv = _tv_distance(counts, p_exact)
    assert tv < 0.05, tv
    for wrong_temp in (temp * 2.0, temp * 0.5):
        tv_wrong = _tv_distance(counts, _enumerate_boltzmann(pdense, wrong_temp))
        assert tv_wrong > 3.0 * tv, (tv, tv_wrong, wrong_temp)


@pytest.mark.slow
def test_colored_chain_matches_rsa_distribution():
    """Cross-engine check: the colored block-Gibbs chain and the single-flip
    RSA chain target the same measure, so their empirical laws on the same
    instance must agree within the cross-mode TV gate used for rsa/rwa."""
    problem = _tiny_sparse_problem()
    temp = 2.5
    n = problem.num_spins
    _, idx_c, pdense = _colored_chain_energies_and_samples(
        problem, temp, r=16, chunk=48, num_chunks=520, burn_chunks=40)
    _, idx_s = _chain_energies_and_samples(
        pdense, temp, mode="rsa", uniformized=False, r=16,
        chunk=48, num_chunks=520, burn_chunks=40)
    emp_c = np.bincount(idx_c, minlength=2 ** n).astype(np.float64)
    emp_s = np.bincount(idx_s, minlength=2 ** n).astype(np.float64)
    tv_cross = 0.5 * np.abs(emp_c / emp_c.sum() - emp_s / emp_s.sum()).sum()
    assert tv_cross < 0.07, tv_cross


def test_colored_zero_temperature_descent_is_monotone():
    """Default-tier colored smoke: at T=0 every class member flips iff it
    lowers energy off live fields — the chunk-boundary energy trajectory is
    monotone non-increasing."""
    problem = _tiny_sparse_problem(seed=2, n=10, m=18)
    energies, _, _ = _colored_chain_energies_and_samples(
        problem, 0.0, r=8, chunk=16, num_chunks=12, burn_chunks=12)
    assert np.isfinite(energies).all()
    assert (np.diff(energies, axis=0) <= 1e-6).all(), \
        "zero-T colored chain increased energy"


def test_zero_temperature_energy_bookkeeping_consistent():
    problem = _tiny_problem(seed=5, n=10)
    base = jax.random.fold_in(jax.random.key(0), jnp.uint32(3))
    state = ops.fused_init_state(problem, base, 8, interpret=True)
    temps = jnp.zeros((64, 8), jnp.float32)
    state = ops.fused_sweep_chunk(
        problem.couplings, state, rng.stream(base, rng.Salt.SWEEP, 0),
        64, temps, mode="rsa", pwl_table=None, block_r=8, interpret=True)
    recomputed = np.asarray(ising.energy(problem, state[1]))
    np.testing.assert_allclose(np.asarray(state[2]), recomputed, atol=1e-3)
    best_recomputed = np.asarray(ising.energy(problem, state[4]))
    np.testing.assert_allclose(np.asarray(state[3]), best_recomputed, atol=1e-3)
