"""PWL logistic approximation (paper §IV-B3a) and TTS statistics (Eq. 32)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips only @given tests when absent

from repro.core import pwl, tts


@pytest.mark.parametrize("segments,zmax", [(32, 8.0), (64, 8.0), (128, 12.0)])
def test_pwl_sigmoid_error_within_analytic_bound(segments, zmax):
    f = pwl.make_pwl_sigmoid(segments, zmax)
    x = np.linspace(-zmax * 1.5, zmax * 1.5, 20001).astype(np.float32)
    approx = np.asarray(f(jnp.asarray(x)))
    exact = 1.0 / (1.0 + np.exp(-x.astype(np.float64)))
    err = np.abs(approx - exact)
    bound = pwl.pwl_error_bound(segments, zmax) + np.float32(1e-6)
    # Tail clamp error: σ(zmax) vs 1 — include it in the tolerance.
    tail = 1.0 / (1.0 + math.exp(zmax))
    assert err.max() <= bound + tail


def test_flip_probability_limits():
    """Paper Fig. 3 behaviour: T→∞ ⇒ 0.5; T→0+ ⇒ {1, 0.5, 0} by sign of ΔE."""
    fp = pwl.exact_flip_probability
    de = jnp.asarray([-3.0, 0.0, 3.0])
    hot = np.asarray(fp(de, jnp.float32(1e8)))
    np.testing.assert_allclose(hot, 0.5, atol=1e-6)
    cold = np.asarray(fp(de, jnp.float32(0.0)))
    np.testing.assert_array_equal(cold, [1.0, 0.5, 0.0])
    warm = np.asarray(fp(de, jnp.float32(1.0)))
    assert 0.0 < warm[2] < 0.5 < warm[0] < 1.0  # uphill suppressed, downhill favoured


def test_pwl_flip_probability_close_to_exact():
    fp_pwl = pwl.pwl_flip_probability
    fp_exact = pwl.exact_flip_probability
    de = jnp.linspace(-20, 20, 401)
    for T in (0.5, 1.0, 4.0):
        a = np.asarray(fp_pwl(de, jnp.float32(T)))
        b = np.asarray(fp_exact(de, jnp.float32(T)))
        assert np.abs(a - b).max() < 2e-3


def test_tts_formula_reference_values():
    # Table III spot checks: Neal t_a=4610ms, P_a=0.38 -> TTS ~ 44413ms.
    assert tts.tts(0.38, 4610.0) == pytest.approx(44413, rel=0.01)
    # STATICA: t_a=0.13ms, P_a=0.07 -> 8.23ms.
    assert tts.tts(0.07, 0.13) == pytest.approx(8.23, rel=0.01)
    # Snowball: P_a=0.99 >= p ⇒ TTS = t_a.
    assert tts.tts(0.99, 0.128) == pytest.approx(0.128)


def test_tts_edge_cases():
    assert math.isinf(tts.tts(0.0, 1.0))
    assert tts.tts(1.0, 2.0) == 2.0
    with pytest.raises(ValueError):
        tts.tts(0.5, 1.0, target=1.0)


@settings(max_examples=50, deadline=None)
@given(st.floats(1e-6, 0.98), st.floats(1e-3, 1e3))
def test_tts_monotone_in_success_probability(p, t_a):
    assert tts.tts(p, t_a) >= tts.tts(min(p * 1.5, 0.99), t_a) - 1e-9


def test_estimate_from_replicas():
    best = np.array([-10.0, -8.0, -10.0, -9.0])
    r = tts.estimate(best, threshold=-10.0, time_per_run=2.0)
    assert r.success_probability == 0.5
    assert r.num_successes == 2
    assert r.tts == pytest.approx(2.0 * math.log(0.01) / math.log(0.5), rel=1e-9)


def test_success_probability_empty_agrees_with_estimate():
    """Zero runs ⇒ 0.0 (not NaN), matching ``estimate`` — and no NumPy
    mean-of-empty RuntimeWarning leaks."""
    empty = np.array([], np.float32)
    with np.errstate(invalid="raise"):
        p = tts.success_probability(empty, threshold=-10.0)
    assert p == 0.0
    r = tts.estimate(empty, threshold=-10.0, time_per_run=2.0)
    assert r.success_probability == p == 0.0
    assert r.num_runs == 0 and r.num_successes == 0
    assert math.isinf(r.tts)


def test_success_probability_all_inf_energies():
    """Runs that never found a finite energy are failures, not NaNs."""
    best = np.full(4, np.inf, np.float32)
    p = tts.success_probability(best, threshold=-10.0)
    assert p == 0.0
    r = tts.estimate(best, threshold=-10.0, time_per_run=1.0)
    assert r.success_probability == 0.0 and math.isinf(r.tts)


def test_success_probability_at_or_above_target_gives_single_run_tts():
    """P_a ≥ p (every replica hit the target) ⇒ one run suffices, TTS = t_a —
    for both the bare estimator and ``estimate``."""
    best = np.array([-12.0, -11.0, -10.0])
    p = tts.success_probability(best, threshold=-10.0)
    assert p == 1.0
    r = tts.estimate(best, threshold=-10.0, time_per_run=3.5, target=0.99)
    assert r.success_probability == 1.0
    assert r.tts == 3.5
