"""Per-architecture smoke tests (deliverable f): reduced configs of the same
family run one forward + one train step on CPU; output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_decode_cache, init_params,
                          model_specs, param_count)

B, S = 2, 32

#: Smoke configs that still take ~a minute per test on CPU — slow tier only
#: (the hybrid 398B family keeps full default-tier coverage via its smaller
#: siblings; run `-m slow` for the complete matrix).
_SLOW_ARCHS = {"jamba-1.5-large-398b"}


def _arch_cases(ids):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
            for a in ids]


def _inputs(cfg, key):
    if cfg.uses_token_embedding:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        return {"tokens": toks}
    return {"embeddings": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)}


@pytest.fixture(scope="module")
def arch_params():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, smoke=True)
            cache[arch] = (cfg, init_params(model_specs(cfg), jax.random.key(0)))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", _arch_cases(ARCH_IDS))
def test_forward_shapes_and_finiteness(arch, arch_params):
    cfg, params = arch_params(arch)
    out = forward(cfg, params, **_inputs(cfg, jax.random.key(1)))
    assert out.logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.logits.astype(jnp.float32))))
    if cfg.num_experts:
        assert float(out.aux_loss) > 0.0  # load-balance loss is active
        assert out.expert_load is not None
    else:
        assert float(out.aux_loss) == 0.0


@pytest.mark.parametrize("arch", _arch_cases(ARCH_IDS))
def test_one_train_step_reduces_loss_direction(arch, arch_params):
    """One SGD step on the smoke config: grads finite, loss finite, params move."""
    cfg, params = arch_params(arch)
    inputs = _inputs(cfg, jax.random.key(2))
    labels = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab_size)

    def loss_fn(p):
        out = forward(cfg, p, **inputs)
        logits = out.logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - picked) + out.aux_loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in flat)
    gnorm = float(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in flat)) ** 0.5
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", _arch_cases(
    ["qwen2-7b", "starcoder2-7b", "phi3.5-moe-42b-a6.6b",
     "rwkv6-1.6b", "jamba-1.5-large-398b", "granite-moe-1b-a400m"]))
def test_decode_matches_forward(arch, arch_params):
    """Prefill-free decode loop reproduces the full forward (KV/state caches)."""
    cfg, params = arch_params(arch)
    toks = jax.random.randint(jax.random.key(4), (B, 16), 0, cfg.vocab_size)
    full = forward(cfg, params, tokens=toks).logits.astype(jnp.float32)
    cache = init_decode_cache(cfg, B, max_len=16)
    outs = []
    for t in range(16):
        lg, cache = decode_step(cfg, params, cache, jnp.int32(t), tokens=toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(full - dec))) / scale < 0.03  # bf16 path difference


def test_encoder_is_bidirectional(arch_params):
    """hubert: flipping a late frame changes logits of an early position."""
    cfg, params = arch_params("hubert-xlarge")
    emb = jax.random.normal(jax.random.key(5), (1, S, cfg.d_model), jnp.bfloat16)
    out1 = forward(cfg, params, embeddings=emb).logits
    emb2 = emb.at[:, -1].set(-emb[:, -1])
    out2 = forward(cfg, params, embeddings=emb2).logits
    assert float(jnp.max(jnp.abs((out1 - out2)[:, 0].astype(jnp.float32)))) > 1e-6


def test_causal_lm_is_causal(arch_params):
    """qwen2: flipping a late token must NOT change earlier logits."""
    cfg, params = arch_params("qwen2-7b")
    toks = jax.random.randint(jax.random.key(6), (1, S), 0, cfg.vocab_size)
    out1 = forward(cfg, params, tokens=toks).logits
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    out2 = forward(cfg, params, tokens=toks2).logits
    diff = jnp.abs((out1 - out2)[:, :-1].astype(jnp.float32))
    assert float(jnp.max(diff)) == 0.0


def test_full_config_param_counts_match_billing():
    """Full configs match their advertised scale (within naming tolerance)."""
    expected = {  # advertised params (rough), tolerance ±35%
        "starcoder2-7b": 7e9,
        "stablelm-12b": 12e9,
        "nemotron-4-340b": 340e9,
        "qwen2-7b": 7e9,
        "llava-next-34b": 34e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
        "granite-moe-1b-a400m": 1.3e9,
        "rwkv6-1.6b": 1.6e9,
        "jamba-1.5-large-398b": 398e9,
    }
    for arch, target in expected.items():
        cfg = get_config(arch)
        n = cfg.param_count()
        assert 0.65 * target < n < 1.35 * target, f"{arch}: {n:.3g} vs {target:.3g}"


def test_moe_active_params_smaller():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()
    granite = get_config("granite-moe-1b-a400m")
    assert granite.active_param_count() < granite.param_count()
